"""Decode hot-loop bench: tokens/s, dispatches and host syncs per token.

Measures what the fused macro-step actually buys (SERVING.md §The
decode hot loop): for each engine and macro-step size K, replay the
same deterministic mixed-length trace as `benchmarks/paged_bench.py`
(scenario-modulated arrivals) and report

* ``tok_per_s``                wall-clock generated tokens per second,
* ``mfu`` / ``mbu``            nominal distance-to-roof (one TPU v5e
                               chip sustaining the measured rate):
                               model-flops and resident-bytes
                               utilization per `launch.hlo_analysis`
                               — the columns every kernel/format PR
                               moves (quantization shrinks the bytes
                               term, so equal tok/s costs less MBU),
* ``dispatches_per_token``     decode jit dispatches / generated token
                               (counted by `src/repro/serving/instrument.py`),
* ``syncs_per_token``          device->host materializations / token,
* ``steady_syncs_per_token``   1 / (most tokens emitted by one
                               macro-step) — the steady-state bound,
                               <= 1/K whenever any macro-step ran a
                               full-budget scan,
* ``uploads_per_token``        block-table re-uploads / token (paged
                               engines; the incremental-snapshot win),
* ``goodput``                  fraction of trace requests meeting their
                               QoS class's TTFT+TPOT deadlines
                               (classes cycled deterministically over
                               the trace; engine-step-clock metric, so
                               deterministic — see
                               `benchmarks/goodput_bench.py` for the
                               policy comparison),
* ``outputs_match``            greedy token streams identical to the
                               reference cell (first engine at the
                               first K) — the hot loop must never trade
                               correctness for speed.

Wall-clock tok/s is host-dependent (as in pipeline/paged benches); the
dispatch/sync/upload columns and the outputs are deterministic given
``--seed``.  Every pow2 scan program <= K (and the prefill chunk
shapes) is compiled during an untimed warmup, so the timed phase
compares steady-state execution.

The default geometry is the *edge* regime the hot loop targets: a
narrow decode batch (2 rows — a device serving a couple of concurrent
streams) and a decode-dominant variant of the paged mixed-length trace
(``short_frac``/``new_lo``/``new_hi`` shifted toward chat-length
prompts with long generations, so requests spend most steps
generating, not admitting).  At wide batch the per-dispatch overhead
is already amortized *across rows* and per-row model compute
dominates, so K buys little; at edge widths every token pays a
dispatch + sync and the macro-step is the difference between
host-bound and compute-bound (ARCHITECTURE.md dataflow note).

Default architecture is batch-decoupled (smollm-360m) so outputs_match
compares cache/loop correctness, not MoE co-batch policy
(see `benchmarks/paged_bench.py`'s config caveats).

  PYTHONPATH=src python -m benchmarks.engine_bench --quick
  PYTHONPATH=src python -m benchmarks.engine_bench --out bench_engine.json
"""
from __future__ import annotations

import argparse
import time

import jax

from benchmarks.paged_bench import build_trace
from repro.configs import get_smoke_config
from repro.experiments.results import save_results
from repro.launch.hlo_analysis import mbu, mfu
from repro.serving import (PagedPipelinedEngine, PagedServingEngine,
                           PipelinedEngine, Request, ServingEngine)
from repro.serving.instrument import instrument
from repro.serving.scheduler import goodput

ENGINE_KINDS = ("dense", "pipelined", "paged", "paged_pipelined")
DEFAULT_KS = "1,4,16"
#: deterministic class assignment for the goodput column: request i of
#: the trace gets QOS_CYCLE[i % 3] (mixed-class without reshaping the
#: token trace)
QOS_CYCLE = ("interactive", "standard", "batch")


def make_engine(kind: str, cfg, k: int, *, max_batch, cache_len, max_rows,
                block_size, num_blocks, prefill_chunk, n_stages=2,
                quantization=None):
    if kind == "dense":
        return ServingEngine(cfg, max_batch=max_batch, cache_len=cache_len,
                             prefill_chunk=prefill_chunk, decode_steps=k,
                             quantization=quantization)
    if kind == "pipelined":
        return PipelinedEngine(cfg, n_stages=n_stages, max_batch=max_batch,
                               cache_len=cache_len,
                               prefill_chunk=prefill_chunk, decode_steps=k,
                               quantization=quantization)
    if kind == "paged":
        return PagedServingEngine(cfg, max_rows=max_rows, max_len=cache_len,
                                  block_size=block_size,
                                  num_blocks=num_blocks,
                                  prefill_chunk=prefill_chunk,
                                  decode_steps=k,
                                  quantization=quantization)
    if kind == "paged_pipelined":
        return PagedPipelinedEngine(cfg, n_stages=n_stages,
                                    max_rows=max_rows, max_len=cache_len,
                                    block_size=block_size,
                                    num_blocks=num_blocks,
                                    prefill_chunk=prefill_chunk,
                                    decode_steps=k,
                                    quantization=quantization)
    raise ValueError(f"unknown engine kind {kind!r}; known: {ENGINE_KINDS}")


def _tree_bytes(tree) -> int:
    return int(sum(x.nbytes for x in jax.tree.leaves(tree)))


def resident_bytes(eng) -> tuple:
    """(weight_bytes, kv_pool_bytes) actually resident on the engine.

    Weights are the engine's (possibly quantized — packed q + scales)
    params pytree, so int8/int4 shrink shows up here without any
    format-specific arithmetic; the KV pool is the cache pytree (stage
    caches for pipelined engines).  Pipelined stage params are slices
    of ``eng.params``, counted once.
    """
    if hasattr(eng, "stages"):
        kv = sum(_tree_bytes(st.caches) for st in eng.stages)
    else:
        kv = _tree_bytes(eng.caches)
    return _tree_bytes(eng.params), kv


def warmup(eng, k: int, prefill_chunk: int):
    """Compile outside the timed phase: one request per reachable scan
    length <= K — the pow2 ladder plus K itself when K is not a power
    of two (each budget n compiles the length-n program) — with a
    prompt long enough to cover every prefill-chunk tail shape."""
    p_len = 2 * prefill_chunk  # toks = 2c-1 -> chunks [c] + all pow2 tails
    lengths, n = [], 1
    while n < k:
        lengths.append(n)
        n *= 2
    lengths.append(k)
    for n in lengths:
        eng.submit(Request(id=-1000 - n, prompt=list(range(1, p_len + 1)),
                           max_new_tokens=n))
        eng.run()
    eng.max_macro_tokens = 0  # steady-state stat starts with the trace


def drive(eng, trace, k: int, prefill_chunk: int, reps: int = 3) -> dict:
    """Replay ``trace`` through ``eng`` ``reps`` times (one warmed-up
    engine, so compiled programs are shared) and keep the fastest pass
    for the wall-clock columns — the 2-vCPU CI box jitters far more
    than the effect under test.  Dispatch/sync/upload columns are
    per-pass deltas and identical across passes; so are the outputs
    (asserted — a state leak between passes would break determinism).
    """
    warmup(eng, k, prefill_chunk)
    counts = instrument(eng)
    is_paged = hasattr(eng, "rows")
    # roofline inputs for the MFU/MBU columns (launch.hlo_analysis):
    # model flops/token and the resident bytes a fused decode step must
    # stream (weights once + KV pool); quantized engines report smaller
    # weight_bytes automatically because the packed pytree is measured
    flops_per_token = 2.0 * eng.cfg.num_active_params()
    weight_bytes, kv_pool_bytes = resident_bytes(eng)
    best = None
    outputs = None
    for rep in range(max(1, reps)):
        sync0, tok0 = eng.n_host_syncs, eng.tokens_generated
        disp0 = counts.decode_dispatches
        pre0 = counts.prefill_dispatches
        up0 = eng.pc.n_meta_uploads if is_paged else 0
        rej0, pre_empt0 = len(eng.rejected), (eng.n_preemptions
                                              if is_paged else 0)

        t0_step = eng.t
        pending = [(t + t0_step,
                    Request(id=i, prompt=list(p), max_new_tokens=n,
                            qos=QOS_CYCLE[i % len(QOS_CYCLE)]))
                   for i, (t, p, n) in enumerate(trace)]
        pass_reqs = [r for _, r in pending]
        done = []
        t0 = time.perf_counter()
        while pending or eng.queue or not eng._idle():
            while pending and pending[0][0] <= eng.t:
                eng.submit(pending.pop(0)[1])
            done += eng.step()
        wall = time.perf_counter() - t0

        done = [r for r in done if r.id >= 0]
        outs = {r.id: list(r.out_tokens) for r in done}
        if outputs is None:
            outputs = outs
        elif outs != outputs:
            raise RuntimeError("outputs drifted across bench passes")
        toks = eng.tokens_generated - tok0
        syncs = eng.n_host_syncs - sync0
        disp = counts.decode_dispatches - disp0
        steps = max(eng.t - t0_step, 1)   # engine-clock decode steps
        tok_per_s = toks / wall
        row = {
            "completed": len(done),
            "rejected": len(eng.rejected) - rej0,
            "tokens": toks,
            "wall_s": wall,
            "tok_per_s": tok_per_s,
            # nominal distance-to-roof (one TPU v5e chip sustaining the
            # measured token rate): model flops/token vs PEAK, and
            # weights+KV streamed once per engine step vs HBM_BW
            "mfu": mfu(flops_per_token, tok_per_s),
            "mbu": mbu((weight_bytes + kv_pool_bytes) * steps
                       / max(toks, 1), tok_per_s),
            "flops_per_token": flops_per_token,
            "weight_bytes": weight_bytes,
            "kv_pool_bytes": kv_pool_bytes,
            "engine_steps": int(steps),
            "decode_dispatches": disp,
            "dispatches_per_token": disp / max(toks, 1),
            "prefill_dispatches": counts.prefill_dispatches - pre0,
            "host_syncs": syncs,
            "syncs_per_token": syncs / max(toks, 1),
            "steady_syncs_per_token": 1.0 / max(eng.max_macro_tokens, 1),
            "uploads_per_token": (
                (eng.pc.n_meta_uploads - up0) / max(toks, 1)
                if is_paged else 0.0),
            "preemptions": (eng.n_preemptions - pre_empt0
                            if is_paged else 0),
            # engine-step-clock SLO metric: identical across passes
            "goodput": goodput(pass_reqs),
        }
        if best is None or row["tok_per_s"] > best["tok_per_s"]:
            best = row
    best["outputs"] = outputs
    return best


def main(configs: str = "smollm-360m", scenario: str = "bursty_mmpp",
         n_requests: int = 32, ks: str = DEFAULT_KS,
         engines: str = ",".join(ENGINE_KINDS), max_batch: int = 2,
         cache_len: int = 128, max_rows: int = 2, block_size: int = 16,
         prefill_chunk: int = 16, short_frac: float = 0.9,
         new_lo: int = 48, new_hi: int = 97,
         reps: int = 3, seed: int = 0, out: str | None = None,
         quantization: str | None = None):
    num_blocks = max_batch * cache_len // block_size  # equal token-slots
    k_list = [int(s) for s in str(ks).split(",")]
    kinds = [s.strip() for s in str(engines).split(",")]
    geom = dict(max_batch=max_batch, cache_len=cache_len, max_rows=max_rows,
                block_size=block_size, num_blocks=num_blocks,
                prefill_chunk=prefill_chunk, quantization=quantization)
    rows = []
    for arch in str(configs).split(","):
        cfg = get_smoke_config(arch)
        trace = build_trace(scenario, seed, n_requests, cache_len,
                            short_frac=short_frac, new_lo=new_lo,
                            new_hi=new_hi)
        ref = None
        res = {}
        print(f"\n== {arch} [{scenario}] {n_requests} reqs, "
              f"K in {k_list}, engines {kinds} ==")
        print(f"{'engine':>15s} {'K':>3s} {'tok/s':>8s} {'mfu':>8s} "
              f"{'mbu':>8s} {'disp/tok':>9s} "
              f"{'sync/tok':>9s} {'steady':>7s} {'upld/tok':>9s} "
              f"{'preempt':>7s} {'goodput':>8s} {'match':>6s}")
        for kind in kinds:
            for k in k_list:
                r = drive(make_engine(kind, cfg, k, **geom), trace, k,
                          prefill_chunk, reps=reps)
                outputs = r.pop("outputs")
                if ref is None:
                    ref = outputs
                r["outputs_match"] = outputs == ref
                res[(kind, k)] = r
                print(f"{kind:>15s} {k:3d} {r['tok_per_s']:8.1f} "
                      f"{r['mfu']:8.1e} {r['mbu']:8.1e} "
                      f"{r['dispatches_per_token']:9.4f} "
                      f"{r['syncs_per_token']:9.4f} "
                      f"{r['steady_syncs_per_token']:7.4f} "
                      f"{r['uploads_per_token']:9.4f} "
                      f"{r['preemptions']:7d} "
                      f"{r['goodput']:8.3f} "
                      f"{str(r['outputs_match']):>6s}")
                rows.append({"arch": arch, "engine": kind, "k": k, **r})
        kmax = max(k_list)
        if ("paged", 1) in res and ("paged", kmax) in res and kmax > 1:
            gain = (res[("paged", kmax)]["tok_per_s"]
                    / res[("paged", 1)]["tok_per_s"])
            print(f"paged K={kmax} vs K=1: {gain:.2f}x tokens/s, "
                  f"steady syncs/token "
                  f"{res[('paged', kmax)]['steady_syncs_per_token']:.4f} "
                  f"(bound 1/K = {1.0 / kmax:.4f})")
    if out:
        save_results(out, rows, meta={
            "section": "engine_bench", "scenario": scenario,
            "configs": configs, "n_requests": n_requests, "ks": ks,
            "engines": engines, "seed": seed, "short_frac": short_frac,
            "new_lo": new_lo, "new_hi": new_hi, "reps": reps, **geom,
            "note": "wall_s/tok_per_s are host-dependent; dispatch/sync/"
                    "upload columns and outputs are deterministic given "
                    "the seed"})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--configs", default="smollm-360m")
    ap.add_argument("--scenario", default="bursty_mmpp",
                    help="registered scenario supplying arrival "
                         "modulation (see benchmarks.run --list-scenarios)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--ks", default=DEFAULT_KS,
                    help="comma list of macro-step sizes K")
    ap.add_argument("--engines", default=",".join(ENGINE_KINDS))
    ap.add_argument("--max-batch", type=int, default=2,
                    help="dense slots AND paged rows (edge decode width; "
                         "the paged pool gets the same token-slot budget)")
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--rows", type=int, default=2)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--short-frac", type=float, default=0.9)
    ap.add_argument("--new-lo", type=int, default=48)
    ap.add_argument("--new-hi", type=int, default=97)
    ap.add_argument("--reps", type=int, default=3,
                    help="timed passes per cell; fastest wins (CI boxes "
                         "jitter more than the effect under test)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quantization", default=None,
                    choices=[None, "bf16", "int8", "int4"],
                    help="weight-only format for every engine cell "
                         "(SERVING.md §Quantization)")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized: fewer requests, K in {1,4}, "
                         "monolithic engines only")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.quick:
        args.requests = 12
        args.ks = "1,4"
        args.engines = "dense,paged"
        args.reps = 2
    main(configs=args.configs, scenario=args.scenario,
         n_requests=args.requests, ks=args.ks, engines=args.engines,
         max_batch=args.max_batch, cache_len=args.cache_len,
         max_rows=args.rows, block_size=args.block_size,
         short_frac=args.short_frac, new_lo=args.new_lo,
         new_hi=args.new_hi, reps=args.reps, seed=args.seed, out=args.out,
         quantization=args.quantization)
