"""Kernel microbenches: name,us_per_call,derived CSV.

On CPU the Pallas kernels run in interpret mode (orders of magnitude
slower than compiled TPU); we therefore time the *ref* path (XLA-compiled
jnp) for wall numbers and report the kernels' analytic FLOPs as
`derived` (GFLOP per call) so the CSV stays meaningful on this host.

The weight-only quant sweep (second CSV block) times the deployable
``models.quantize.qdot`` paths at a decode-shaped matmul and reports
weight bytes streamed + achieved GB/s against the dense bf16 baseline
— the byte-traffic race that makes quantization a decode win
(SERVING.md §Quantization).  The f32 row is the CPU transparency cell:
XLA emulates bf16 on this host, so dense-bf16 walltime is pessimistic
relative to TPU; bytes are exact either way.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.models import quantize as qz


def _time_us(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main():
    key = jax.random.PRNGKey(0)
    rows = []

    b, h, kv, s, d = 1, 8, 2, 1024, 128
    q = jax.random.normal(key, (b, h, s, d), jnp.float32)
    k = jax.random.normal(key, (b, kv, s, d), jnp.float32)
    v = jax.random.normal(key, (b, kv, s, d), jnp.float32)
    fa = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    us = _time_us(fa, q, k, v)
    flops = 4 * b * h * s * s * d / 2  # causal
    rows.append(("flash_attention_1k", us, flops / 1e9))

    s2 = 8192
    kc = jax.random.normal(key, (b, kv, s2, d), jnp.float32)
    vc = jax.random.normal(key, (b, kv, s2, d), jnp.float32)
    qd = jax.random.normal(key, (b, h, d), jnp.float32)
    pos = jnp.full((b,), s2 - 1, jnp.int32)
    da = jax.jit(lambda q, k, v, p: ref.decode_attention_ref(q, k, v, p))
    us = _time_us(da, qd, kc, vc, pos)
    rows.append(("decode_attention_8k", us, 4 * b * h * s2 * d / 1e9))

    bt, t, di, ds = 2, 512, 512, 16
    dt = jax.nn.softplus(jax.random.normal(key, (bt, t, di)))
    bm = jax.random.normal(key, (bt, t, ds))
    cm = jax.random.normal(key, (bt, t, ds))
    x = jax.random.normal(key, (bt, t, di))
    an = -jnp.abs(jax.random.normal(key, (di, ds)))
    h0 = jnp.zeros((bt, di, ds))
    ss = jax.jit(lambda *a: ref.selective_scan_ref(*a))
    us = _time_us(ss, dt, bm, cm, x, an, h0)
    rows.append(("selective_scan_512", us, 8 * bt * t * di * ds / 1e9))

    xn = jax.random.normal(key, (4096, 1024))
    sc = jnp.ones((1024,))
    rn = jax.jit(lambda x, s: ref.rmsnorm_ref(x, s))
    us = _time_us(rn, xn, sc)
    rows.append(("rmsnorm_4kx1k", us, 4096 * 1024 * 4 / 1e9))

    print("name,us_per_call,derived_gflop")
    for name, us, gf in rows:
        print(f"{name},{us:.1f},{gf:.3f}")

    # ---- weight-only quant matmuls (decode shape: 4 rows) ----
    m, kq, nq = 4, 2048, 4096
    kx, kw = jax.random.split(key)
    w32 = jax.random.normal(kw, (kq, nq), jnp.float32)
    x32 = jax.random.normal(kx, (m, kq), jnp.float32)
    cells = [
        ("dense_bf16", x32.astype(jnp.bfloat16), w32.astype(jnp.bfloat16)),
        ("dense_f32", x32, w32),
        ("int8", x32, qz.quantize_int8(w32)),
        ("int4", x32, qz.quantize_int4(w32)),
    ]
    qdot = jax.jit(qz.qdot)
    print("\nname,us_per_call,weight_mb,achieved_gbps")
    base_us = None
    for name, x, w in cells:
        us = _time_us(qdot, x, w)
        if base_us is None:
            base_us = us        # dense bf16 is the comparison row
        wb = sum(leaf.nbytes for leaf in jax.tree.leaves(w))
        print(f"quant_matmul_{name}_2kx4k,{us:.1f},{wb / 1e6:.2f},"
              f"{wb / (us * 1e-6) / 1e9:.2f}")
    print(f"# int8/int4 rows stream {2 * kq * nq / 1e6:.1f}MB of bf16 "
          f"weight as packed ints; speedup vs dense bf16 is in "
          f"bench_quant.json (engine-level criterion)")


if __name__ == "__main__":
    main()
