"""Kernel microbenches: name,us_per_call,derived CSV.

On CPU the Pallas kernels run in interpret mode (orders of magnitude
slower than compiled TPU); we therefore time the *ref* path (XLA-compiled
jnp) for wall numbers and report the kernels' analytic FLOPs as
`derived` (GFLOP per call) so the CSV stays meaningful on this host.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _time_us(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main():
    key = jax.random.PRNGKey(0)
    rows = []

    b, h, kv, s, d = 1, 8, 2, 1024, 128
    q = jax.random.normal(key, (b, h, s, d), jnp.float32)
    k = jax.random.normal(key, (b, kv, s, d), jnp.float32)
    v = jax.random.normal(key, (b, kv, s, d), jnp.float32)
    fa = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    us = _time_us(fa, q, k, v)
    flops = 4 * b * h * s * s * d / 2  # causal
    rows.append(("flash_attention_1k", us, flops / 1e9))

    s2 = 8192
    kc = jax.random.normal(key, (b, kv, s2, d), jnp.float32)
    vc = jax.random.normal(key, (b, kv, s2, d), jnp.float32)
    qd = jax.random.normal(key, (b, h, d), jnp.float32)
    pos = jnp.full((b,), s2 - 1, jnp.int32)
    da = jax.jit(lambda q, k, v, p: ref.decode_attention_ref(q, k, v, p))
    us = _time_us(da, qd, kc, vc, pos)
    rows.append(("decode_attention_8k", us, 4 * b * h * s2 * d / 1e9))

    bt, t, di, ds = 2, 512, 512, 16
    dt = jax.nn.softplus(jax.random.normal(key, (bt, t, di)))
    bm = jax.random.normal(key, (bt, t, ds))
    cm = jax.random.normal(key, (bt, t, ds))
    x = jax.random.normal(key, (bt, t, di))
    an = -jnp.abs(jax.random.normal(key, (di, ds)))
    h0 = jnp.zeros((bt, di, ds))
    ss = jax.jit(lambda *a: ref.selective_scan_ref(*a))
    us = _time_us(ss, dt, bm, cm, x, an, h0)
    rows.append(("selective_scan_512", us, 8 * bt * t * di * ds / 1e9))

    xn = jax.random.normal(key, (4096, 1024))
    sc = jnp.ones((1024,))
    rn = jax.jit(lambda x, s: ref.rmsnorm_ref(x, s))
    us = _time_us(rn, xn, sc)
    rows.append(("rmsnorm_4kx1k", us, 4096 * 1024 * 4 / 1e9))

    print("name,us_per_call,derived_gflop")
    for name, us, gf in rows:
        print(f"{name},{us:.1f},{gf:.3f}")


if __name__ == "__main__":
    main()
