PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test smoke docs ci

# tier-1: must collect and pass with or without hypothesis installed
test:
	$(PY) -m pytest -x -q

# CI-sized end-to-end gate: fig3/fig4 through the parallel replication
# runner on the baseline scenario, machine-readable JSON outputs
smoke:
	$(PY) -m benchmarks.run --quick --scenario baseline

# docs gate: every relative link in *.md resolves, and the README
# quickstart runs end-to-end
docs:
	$(PY) tools/check_docs.py
	$(PY) examples/quickstart.py

ci: test smoke docs
