PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: lint typecheck test test-full smoke simbench engine-bench \
        goodput-bench spec-bench quant-bench docs ci

# line-coverage floor over the serving-critical modules (serving/,
# core/, models/kvcache.py): measured tier-1 baseline (89.5%) minus
# one point — see tools/covgate.py and TOOLING.md §Coverage gate
COV_FLOOR ?= 88.5

# invariant linter (tools/reprolint/): AST rules for the serving
# stack's contracts — jit donation, host-sync budget, seeded RNG,
# jax-free host layer, step-counter clock, ledger privacy.  See
# TOOLING.md for the rule catalogue and suppression syntax; --json
# for machine-readable output
lint:
	$(PY) -m tools.reprolint src benchmarks tests

# typecheck gate over the curated host-layer modules (pyright, else
# mypy, else a syntax-only fallback — see tools/typecheck.py)
typecheck:
	$(PY) tools/typecheck.py

# tier-1 under the coverage gate: fast tests only (tier2 marks the
# slow parity sweeps — TOOLING.md §Test tiers), must collect and pass
# with or without hypothesis installed
test:
	$(PY) tools/covgate.py --floor $(COV_FLOOR) -- -x -q -m "not tier2"

# both tiers: the full parity sweeps across every architecture
test-full:
	$(PY) -m pytest -x -q

# CI-sized end-to-end gate: fig3/fig4 through the parallel replication
# runner on the baseline scenario, machine-readable JSON outputs
smoke:
	$(PY) -m benchmarks.run --quick --scenario baseline

# vectorized-vs-scalar simulator smoke: metric equality gates; the
# printed trials/s + speedup-vs-floor are informational (noisy boxes)
simbench:
	$(PY) -m benchmarks.sim_bench --quick

# decode hot-loop bench, full size: refreshes the committed
# bench_engine.json baseline (the `make smoke` chain writes CI-sized
# numbers to the scratch bench_engine_quick.json instead)
engine-bench:
	$(PY) -m benchmarks.engine_bench --out bench_engine.json
	$(PY) -m benchmarks.report --engine bench_engine.json

# SLO-goodput bench, full size: refreshes the committed
# bench_goodput.json baseline (deterministic FakeEngine trace; the
# `make smoke` chain writes CI-sized numbers to bench_goodput_quick.json)
goodput-bench:
	$(PY) -m benchmarks.goodput_bench --out bench_goodput.json
	$(PY) -m benchmarks.report --goodput bench_goodput.json

# speculative-decoding bench, full size: refreshes the committed
# bench_spec.json baseline (best spec cell must clear 1.3x the paged
# K=16 macro-step baseline — SERVING.md §Speculative decoding; the
# `make smoke` chain writes CI-sized numbers to bench_spec_quick.json)
spec-bench:
	$(PY) -m benchmarks.spec_bench --out bench_spec.json
	$(PY) -m benchmarks.report --spec bench_spec.json

# weight-only quantization bench, full size: refreshes the committed
# bench_quant.json baseline (int8 paged K=16 must clear 1.4x the bf16
# cell and every golden gate — SERVING.md §Quantization; the
# `make smoke` chain writes CI-sized numbers to bench_quant_quick.json)
quant-bench:
	$(PY) -m benchmarks.quant_bench --out bench_quant.json
	$(PY) -m benchmarks.report --quant bench_quant.json

# docs gate: every relative link in *.md resolves, quoted source-file
# references in README/ARCHITECTURE/EXPERIMENTS/SERVING point at real
# files, and the README quickstart runs end-to-end
docs:
	$(PY) tools/check_docs.py
	$(PY) examples/quickstart.py

ci: lint typecheck test smoke simbench docs
